#!/usr/bin/env bash
# Single-command CI gate: tier-1 pytest + a 10-request elastic serve smoke.
#   ./scripts/smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== serve smoke (10 requests, elastic k: 1 -> 2 -> 1) =="
python -m repro.launch.serve --arch smollm-360m --smoke --trace poisson \
    --requests 10 --seed 0

echo "== traced serve run + Chrome trace validation =="
python -m repro.launch.serve --arch smollm-360m --smoke --trace poisson \
    --requests 8 --kv-layout paged --trace-out /tmp/serve_trace.json --seed 0
python -m repro.obs.trace --validate /tmp/serve_trace.json \
    --require schedule,admit,prefill.dispatch,decode.dispatch,device_wait

echo "== overlapped traced serve (async tick pipeline spans) =="
python -m repro.launch.serve --arch smollm-360m --smoke --trace poisson \
    --requests 8 --kv-layout paged --overlap \
    --trace-out /tmp/overlap_trace.json --seed 0
python -m repro.obs.trace --validate /tmp/overlap_trace.json \
    --require overlap.prep,overlap.bind,overlap.inflight,prefill.device_wait

echo "== overlapped paged+spec vs flat A/B (dry run) =="
python benchmarks/serve_bench.py --ab --overlap --dry-run

echo "== disabled-tracing overhead guard =="
python -m pytest -q tests/test_obs.py -k overhead

echo "== paged-attention kernel parity (Pallas interpret vs jnp oracle) =="
python -m repro.kernels.paged_attention --selftest

echo "== KV memory manager invariants (refcount/COW/park fuzz) =="
python -m repro.serve.memory --selftest

echo "== disagg traced serve (prefill/decode pools + handoff spans) =="
python -m repro.launch.serve --arch smollm-360m --smoke --trace poisson \
    --requests 10 --disagg --workers 2 --trace-out /tmp/disagg_trace.json \
    --seed 0
python -m repro.obs.trace --validate /tmp/disagg_trace.json \
    --require schedule,prefill.dispatch,decode.dispatch,handoff.extract,handoff.inject \
    --require-tracks prefill_pool.prefill,decode_pool.decode,handoff

echo "== paged-vs-flat serve A/B (dry run) =="
python benchmarks/serve_bench.py --ab --dry-run

echo "== speculative-decode on/off A/B (dry run) =="
python benchmarks/serve_bench.py --spec --dry-run

echo "== prefix-sharing on/off A/B (dry run) =="
python benchmarks/serve_bench.py --share --dry-run

echo "== disagg-vs-monolithic serve A/B (dry run) =="
python benchmarks/serve_bench.py --disagg --dry-run

echo "== chaos smoke (injected crash + recovery spans in the trace) =="
python -m repro.launch.serve --arch smollm-360m --smoke --trace poisson \
    --requests 8 --kv-layout paged --workers 2 --scale-events "" \
    --chaos "crash@t=5" --trace-out /tmp/chaos_trace.json --seed 0
python -m repro.obs.trace --validate /tmp/chaos_trace.json \
    --require fault.inject,recovery.crash,recovery.requeue,recovery.done

echo "== fault-free vs injected-crash A/B (dry run) =="
python benchmarks/serve_bench.py --chaos --dry-run

echo "== overload smoke (tight SLOs + admission + brownout + breaker) =="
python -m repro.launch.serve --arch smollm-360m --smoke --trace poisson \
    --requests 12 --kv-layout paged --workers 2 --scale-events "" \
    --slo-ttft 0.05 --slo-tpot 0.02 --tenant-rate 8 --queue-cap 6 \
    --brownout auto --chaos "crash@t=2" --trace-out /tmp/overload_trace.json \
    --seed 0
python -m repro.obs.trace --validate /tmp/overload_trace.json \
    --require slo.miss,degrade.enter,breaker.open

echo "== overload-control A/B (dry run) =="
python benchmarks/serve_bench.py --overload --dry-run

echo "== cluster smoke (2 trainers + 1 server, fair-share orchestrator) =="
python examples/cluster_mix.py --fast
python benchmarks/cluster_bench.py --dry-run

echo "smoke OK"
